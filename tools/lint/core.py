"""repro-lint framework core: rule registry, file walking, pragma
suppression, baseline accounting, and the lint runner.

The repo's reproducibility guarantees (counter-based CRN draws,
injected clocks, semantics-version cache salts, xp-generic scenario
code, loud env validation) are *conventions* — each was violated once
and fixed by hand before this tool existed (see docs/linting.md for
the rule-by-rule history).  This framework mechanizes them:

  * a :class:`Rule` inspects Python ASTs (or markdown text) and emits
    :class:`Finding` rows; rules register themselves into
    :data:`RULES` at import time (``tools.lint.rules``);
  * per-line ``# repro-lint: disable=<rule>[,<rule>]`` pragmas
    suppress findings where the violation is justified in place;
  * a committed baseline (``tools/lint/baseline.json``) grandfathers
    pre-existing findings by line-content fingerprint, so the tool can
    gate CI at zero *new* findings without a flag-day cleanup;
  * :func:`run_lint` returns a :class:`Report`; the CLI lives in
    ``tools.lint.__main__`` (``python -m tools.lint [paths]``).

Everything here is stdlib-only: the lint job must run without jax,
numpy, or an installed package (CI runs it before ``pip install``).
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: directory components never descended into during a directory walk
#: (explicit file arguments are always linted — that is how the test
#: suite points the tool at the deliberately-violating fixtures under
#: ``tools/lint/testdata/``)
EXCLUDE_PARTS = {".git", "__pycache__", ".pytest_cache", "results",
                 "build", "dist", ".eggs", "node_modules", "testdata"}

#: suffixes the walker collects; rules narrow further via ``suffixes``
LINT_SUFFIXES = (".py", ".md")

#: default lint surface when the CLI is given no paths: the acceptance
#: surface (src/tools/benchmarks) plus the documentation tree, so the
#: doc rules keep the coverage the standalone check_docs.py had
DEFAULT_PATHS = ("src", "tools", "benchmarks", "docs", "README.md",
                 "ROADMAP.md", "CHANGES.md")

DEFAULT_BASELINE = Path("tools/lint/baseline.json")

PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s-]+)")

BASELINE_VERSION = 1


class LintConfigError(Exception):
    """Bad invocation or broken lint configuration (exit code 2)."""


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location.

    ``line`` is 1-based; 0 marks a file- or repo-level finding (salt
    pins, missing docstrings) that no line pragma can suppress.
    """
    rule: str
    path: str          # root-relative posix path
    line: int
    message: str

    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path


class Source:
    """One file handed to rules: text, split lines, lazy Python AST."""

    def __init__(self, root: Path, path: Path):
        self.path = path
        try:
            self.rel = path.relative_to(root).as_posix()
        except ValueError:
            self.rel = path.as_posix()
        self.text = path.read_text(encoding="utf-8")
        self.lines = self.text.splitlines()
        self._tree: Optional[ast.Module] = None
        self.parse_error: Optional[SyntaxError] = None

    @property
    def tree(self) -> ast.Module:
        if self._tree is None:
            self._tree = ast.parse(self.text)
        return self._tree

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Context:
    """Run-wide state shared by rules: the repo root, the selected
    files, and a parse cache for off-selection files (salt surfaces,
    registry definitions)."""

    def __init__(self, root: Path, files: Sequence[Path]):
        self.root = Path(root).resolve()
        self.files = list(files)
        self._sources: Dict[Path, Source] = {}

    def source(self, path: Path) -> Source:
        path = Path(path)
        if not path.is_absolute():
            path = self.root / path
        path = path.resolve()
        if path not in self._sources:
            self._sources[path] = Source(self.root, path)
        return self._sources[path]

    def selected(self, suffixes: Tuple[str, ...]) -> Iterable[Source]:
        for f in self.files:
            if f.suffix in suffixes:
                yield self.source(f)


class Rule:
    """Base rule: subclass, set ``name``/``contract``, implement
    ``check_source`` (per selected file) and/or ``check_repo`` (once
    per run, for rules whose surface is fixed repo state rather than
    the CLI selection).

    ``default = False`` keeps a rule out of the no-``--rules`` run
    while leaving it selectable by name — that is how the jax-costing
    ``ir-*`` family (``tools/graphlint``) shares this registry without
    breaking the stdlib-only CI lint job.
    """

    name: str = ""
    contract: str = ""
    suffixes: Tuple[str, ...] = (".py",)
    default: bool = True

    def check_source(self, src: Source,
                     ctx: Context) -> Iterable[Finding]:
        return ()

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        return ()


#: rule-name -> rule instance; populated by ``tools.lint.rules``
RULES: Dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate and register a :class:`Rule`."""
    rule = cls()
    if not rule.name:
        raise LintConfigError(f"rule {cls.__name__} has no name")
    if rule.name in RULES:
        raise LintConfigError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return cls


# ----------------------------------------------------------------------
# Shared AST helpers
# ----------------------------------------------------------------------

class ImportMap:
    """Alias-aware dotted-name resolution for one module.

    Tracks ``import``/``from`` bindings so rules can resolve
    ``np.random.default_rng`` / ``from time import monotonic`` /
    ``import jax.numpy as jnp`` uniformly to canonical dotted paths —
    matching on surface spelling would miss every aliased import.
    """

    def __init__(self, tree: ast.Module):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.aliases[a.asname] = a.name
                    else:
                        # ``import numpy.random`` binds ``numpy``
                        head = a.name.split(".")[0]
                        self.aliases[head] = head
            elif isinstance(node, ast.ImportFrom):
                if node.level or not node.module:
                    continue               # relative imports: repo code
                for a in node.names:
                    if a.name == "*":
                        continue
                    bound = a.asname or a.name
                    self.aliases[bound] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted path for a Name/Attribute chain, or None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name) and node.id in self.aliases:
            return ".".join([self.aliases[node.id]] + parts[::-1])
        return None


def names_in(node: ast.AST) -> Iterable[str]:
    """All Name identifiers read anywhere under ``node``."""
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id


def in_zone(rel: str, zones: Sequence[str]) -> bool:
    """True when a root-relative path falls under any zone prefix
    (zones ending in '/' are directories, otherwise exact files)."""
    return any(rel.startswith(z) if z.endswith("/") else rel == z
               for z in zones)


# ----------------------------------------------------------------------
# Pragmas, fingerprints, baseline
# ----------------------------------------------------------------------

def pragma_disabled(line_text: str) -> frozenset:
    """Rule names disabled by a ``# repro-lint: disable=...`` pragma on
    this line (``all`` disables every rule)."""
    m = PRAGMA_RE.search(line_text)
    if not m:
        return frozenset()
    return frozenset(p.strip() for p in m.group(1).split(",")
                     if p.strip())


def pragma_justification(line_text: str) -> str:
    """The parenthesized justification following a pragma's rule list
    (``# repro-lint: disable=r (why: ...)``), or "" when the author
    left none — surfaced in the JSON report so suppressed findings
    stay auditable instead of silently vanishing."""
    m = PRAGMA_RE.search(line_text)
    if not m:
        return ""
    j = re.match(r"\s*\(([^)]*)\)", line_text[m.end():])
    return j.group(1).strip() if j else ""


def fingerprint(finding: Finding, line_text: str) -> str:
    """Line-number-independent identity for baseline accounting: the
    rule, the file, and the *stripped text* of the offending line (the
    message for file-level findings), so unrelated edits above a
    grandfathered finding never churn the baseline."""
    anchor = line_text.strip() if finding.line else finding.message
    raw = f"{finding.rule}\x00{finding.path}\x00{anchor}"
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


def load_baseline(path: Path) -> Dict[str, Dict]:
    """fingerprint -> entry dict (with remaining ``count``)."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    if data.get("version") != BASELINE_VERSION:
        raise LintConfigError(
            f"{path}: baseline version {data.get('version')!r} != "
            f"{BASELINE_VERSION}; regenerate with --write-baseline")
    return {e["fp"]: dict(e) for e in data.get("entries", [])}


def baseline_entries(findings: Sequence[Finding],
                     ctx: Context) -> List[Dict]:
    """Baseline rows for the given findings, fingerprint-deduplicated
    with multiplicity (two identical lines in one file grandfather two
    findings, not unbounded many)."""
    rows: Dict[str, Dict] = {}
    for f in findings:
        text = ""
        if f.line:
            try:
                text = ctx.source(ctx.root / f.path).line_text(f.line)
            except OSError:
                text = ""
        fp = fingerprint(f, text)
        if fp in rows:
            rows[fp]["count"] += 1
        else:
            rows[fp] = {"fp": fp, "rule": f.rule, "path": f.path,
                        "count": 1,
                        "anchor": (text.strip() if f.line
                                   else f.message)[:120]}
    return sorted(rows.values(), key=lambda e: (e["path"], e["rule"],
                                                e["fp"]))


def write_baseline(path: Path, findings: Sequence[Finding],
                   ctx: Context) -> int:
    entries = baseline_entries(findings, ctx)
    payload = {"version": BASELINE_VERSION, "entries": entries}
    path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                    + "\n", encoding="utf-8")
    return len(entries)


# ----------------------------------------------------------------------
# File collection and the runner
# ----------------------------------------------------------------------

def collect_files(root: Path, path_args: Sequence[str]) -> List[Path]:
    """Resolve CLI path arguments to the lintable file list.

    Directories are walked recursively (skipping
    :data:`EXCLUDE_PARTS` components *below* the argument, so
    explicitly pointing at a fixture directory still lints it);
    explicit files are always included.
    """
    out: List[Path] = []
    seen = set()
    for arg in path_args:
        p = Path(arg)
        if not p.is_absolute():
            p = root / p
        if p.is_file():
            candidates = [p]
        elif p.is_dir():
            candidates = [
                f for f in sorted(p.rglob("*"))
                if f.is_file() and f.suffix in LINT_SUFFIXES
                and not any(part in EXCLUDE_PARTS
                            for part in f.relative_to(p).parts)]
        else:
            raise LintConfigError(f"no such path: {arg}")
        for f in candidates:
            r = f.resolve()
            if r not in seen:
                seen.add(r)
                out.append(r)
    return out


@dataclasses.dataclass
class Report:
    findings: List[Finding]            # actionable (gate on these)
    suppressed: List[Finding]          # pragma-silenced
    baselined: List[Finding]           # grandfathered
    stale_baseline: List[Dict]         # entries that no longer match
    checked_files: int
    rules_run: List[str]
    #: per-suppressed-finding justification text, parallel to
    #: ``suppressed`` (a pragma without one contributes "")
    suppressed_justifications: List[str] = \
        dataclasses.field(default_factory=list)

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0

    def to_json(self) -> Dict:
        return {
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "suppressed": len(self.suppressed),
            "suppressed_findings": [
                {**dataclasses.asdict(f), "justification": j}
                for f, j in zip(self.suppressed,
                                self.suppressed_justifications)],
            "baselined": len(self.baselined),
            "stale_baseline": self.stale_baseline,
            "checked_files": self.checked_files,
            "rules": self.rules_run,
            "exit_code": self.exit_code,
        }


def run_lint(root: Path, paths: Sequence[str],
             rule_names: Optional[Sequence[str]] = None,
             baseline_path: Optional[Path] = None,
             use_baseline: bool = True) -> Tuple[Report, Context]:
    """Run the registered rules over ``paths`` and classify findings.

    Returns the report plus the context (the CLI reuses the context
    for ``--write-baseline``).
    """
    import tools.lint.rules  # noqa: F401  (registers RULES lazily)

    root = Path(root).resolve()
    files = collect_files(root, paths or list(DEFAULT_PATHS))
    ctx = Context(root, files)

    if rule_names:
        unknown = sorted(set(rule_names) - set(RULES))
        if unknown:
            raise LintConfigError(
                f"unknown rule(s) {unknown}; registered: "
                f"{sorted(RULES)}")
        active = {n: RULES[n] for n in rule_names}
    else:
        active = {n: r for n, r in RULES.items() if r.default}

    raw: List[Finding] = []
    parsed: Dict[Path, Source] = {}
    for f in files:
        src = ctx.source(f)
        parsed[f] = src
        if f.suffix == ".py":
            try:
                src.tree
            except SyntaxError as e:
                src.parse_error = e
                raw.append(Finding(
                    rule="parse-error", path=src.rel,
                    line=e.lineno or 0,
                    message=f"file does not parse: {e.msg}"))

    for name in sorted(active):
        rule = active[name]
        for src in ctx.selected(rule.suffixes):
            if src.parse_error is not None:
                continue
            raw.extend(rule.check_source(src, ctx))
        raw.extend(rule.check_repo(ctx))

    raw.sort(key=lambda f: (f.path, f.line, f.rule, f.message))

    # pragma suppression (same-line, line-anchored findings only)
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    justifications: List[str] = []
    for f in raw:
        text = ""
        if f.line:
            try:
                text = ctx.source(root / f.path).line_text(f.line)
            except OSError:
                text = ""
        disabled = pragma_disabled(text)
        if f.line and ("all" in disabled or f.rule in disabled):
            suppressed.append(f)
            justifications.append(pragma_justification(text))
        else:
            kept.append(f)

    # baseline subtraction
    baselined: List[Finding] = []
    stale: List[Dict] = []
    if use_baseline:
        bpath = baseline_path or (root / DEFAULT_BASELINE)
        budget = load_baseline(bpath)
        remaining: List[Finding] = []
        for f in kept:
            text = (ctx.source(root / f.path).line_text(f.line)
                    if f.line else "")
            fp = fingerprint(f, text)
            entry = budget.get(fp)
            if entry and entry["count"] > 0:
                entry["count"] -= 1
                baselined.append(f)
            else:
                remaining.append(f)
        kept = remaining
        stale = [e for e in budget.values() if e["count"] > 0]

    return Report(findings=kept, suppressed=suppressed,
                  baselined=baselined, stale_baseline=stale,
                  checked_files=len(files),
                  rules_run=sorted(active),
                  suppressed_justifications=justifications), ctx
