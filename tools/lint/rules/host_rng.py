"""no-host-rng: stateful host RNG is banned from CRN zones, and
global-state RNG is banned everywhere.

Contract (PR 4/7/8): every draw in the compiled engines, the scenario
layer, and the serving traffic/front-end stack must be a counter-based
splitmix64 hash of an explicit key — ``np.random`` Generator streams
have data-dependent call counts that break batch/retry/device-count
composition (the PR 4 jit blocker), and any host RNG in a CRN zone
silently destroys the common-random-numbers property that makes
policy deltas pure policy effects.

  * CRN zones (``scenarios/``, ``serving/``, ``simulator_jit.py``):
    ANY reference to ``np.random``, stdlib ``random``, or
    ``jax.random`` is a finding — keyed splitmix64
    (``repro.scenarios.crn``) is the only sanctioned randomness.
  * Everywhere else: explicitly seeded per-point streams
    (``default_rng``/``Generator``/``SeedSequence``/bit generators)
    are the repo's documented contract and stay legal, as does keyed
    ``jax.random``; module-global draws (``np.random.seed``,
    ``np.random.random``, ...) and the stdlib ``random`` module are
    findings — they are process-order-dependent by construction.
"""
from __future__ import annotations

import ast

from tools.lint.core import (Context, Finding, ImportMap, Rule,
                             Source, in_zone, register)

#: zero-host-RNG zones: only keyed splitmix64 draws are legal here
CRN_ZONES = (
    "src/repro/scenarios/",
    "src/repro/serving/",
    "src/repro/core/simulator_jit.py",
)

#: explicitly-seeded stream constructors allowed outside CRN zones
SEEDED_STREAM_API = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
}


@register
class HostRngRule(Rule):
    name = "no-host-rng"
    contract = ("CRN zones draw only keyed splitmix64; elsewhere host "
                "RNG must be an explicitly seeded per-point stream")

    def check_source(self, src: Source, ctx: Context):
        imap = ImportMap(src.tree)
        crn = in_zone(src.rel, CRN_ZONES)
        reported = set()
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.Attribute, ast.Name)):
                continue
            dotted = imap.resolve(node)
            if dotted is None:
                continue
            kind = _classify(dotted)
            if kind is None:
                continue
            # report each chain once, at its outermost resolution:
            # np.random.default_rng resolves at three nesting levels
            key = (node.lineno, node.col_offset)
            if key in reported:
                continue
            reported.add(key)
            # mark inner positions of this chain as handled
            inner = node
            while isinstance(inner, ast.Attribute):
                inner = inner.value
                reported.add((getattr(inner, "lineno", -1),
                              getattr(inner, "col_offset", -1)))
            if crn:
                yield Finding(
                    self.name, src.rel, node.lineno,
                    f"{dotted} in CRN zone {src.rel!r}: this layer "
                    "must draw via keyed splitmix64 "
                    "(repro.scenarios.crn / the engine's counter "
                    "draws) only")
            elif kind == "global":
                yield Finding(
                    self.name, src.rel, node.lineno,
                    f"{dotted} uses process-global RNG state "
                    "(draw-order dependent); use an explicitly "
                    "seeded np.random.default_rng(seed) stream or a "
                    "keyed splitmix64 draw")

def _classify(dotted: str):
    """'global' (banned everywhere), 'seeded' (banned only in CRN
    zones), or None (not RNG)."""
    if dotted == "random" or dotted.startswith("random."):
        return "global"
    if dotted.startswith("jax.random"):
        return "seeded"
    if dotted == "numpy.random":
        return "seeded"                    # bare namespace reference
    if dotted.startswith("numpy.random."):
        head = dotted.split("numpy.random.", 1)[1].split(".")[0]
        return "seeded" if head in SEEDED_STREAM_API else "global"
    return None
