"""xp-generic: engine-shared code touches only the injected ``xp``
array namespace.

Contract (PR 8): ``scenarios.scenario.demand_multiplier`` and friends
compile into the NumPy engines *and* the jit engine from one source —
the caller injects ``xp`` (``numpy`` or ``jax.numpy``) and the
function must be bit-identical under both.  Reaching for ``jnp``/
``jax`` directly forks the semantics per engine (and drags JAX into
jax-free campaign workers); reaching for ``np`` array *ops* silently
pins the jit path to host numpy (a tracer leak).  Only dtype
constructors/constants and ``np.errstate`` are backend-neutral and
stay legal.

Applies to every function with a parameter named ``xp``, plus the
modules listed in :data:`XP_FILES` that declare themselves xp-generic
at module scope (``scenarios/crn.py``).
"""
from __future__ import annotations

import ast

from tools.lint.core import (Context, Finding, ImportMap, Rule,
                             Source, register)

#: whole files whose module docstring promises xp-genericity
XP_FILES = ("src/repro/scenarios/crn.py",)

#: backend-neutral numpy attributes (dtype constructors, constants,
#: and the overflow-warning guard) — everything else must go via xp
NP_NEUTRAL = {
    "uint8", "uint16", "uint32", "uint64",
    "int8", "int16", "int32", "int64",
    "float16", "float32", "float64", "bool_",
    "errstate", "newaxis", "pi", "inf", "nan", "e",
    "ndarray", "dtype", "integer", "floating", "generic",
}


@register
class XpGenericRule(Rule):
    name = "xp-generic"
    contract = ("xp-parameterized (and XP_FILES) code uses the "
                "injected xp namespace; np only for dtypes/errstate")

    def check_source(self, src: Source, ctx: Context):
        imap = ImportMap(src.tree)
        if src.rel in XP_FILES:
            yield from self._scan(src, src.tree, imap, "module")
            return
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            args = node.args
            all_args = (args.posonlyargs + args.args + args.kwonlyargs)
            if not any(a.arg == "xp" for a in all_args):
                continue
            yield from self._scan(src, node, imap,
                                  f"function {node.name!r}")

    def _scan(self, src: Source, scope: ast.AST, imap: ImportMap,
              where: str):
        reported = set()
        for node in ast.walk(scope):
            if not isinstance(node, (ast.Attribute, ast.Name)):
                continue
            dotted = imap.resolve(node)
            if dotted is None:
                continue
            key = (node.lineno, node.col_offset)
            if key in reported:
                continue
            bad = None
            if dotted == "jax" or dotted.startswith("jax."):
                bad = (f"{dotted} in xp-generic {where}: use the "
                       "injected xp namespace — direct jax use forks "
                       "the engines and drags JAX into jax-free "
                       "workers")
            elif dotted.startswith("numpy."):
                head = dotted.split(".", 1)[1].split(".")[0]
                if head not in NP_NEUTRAL:
                    bad = (f"{dotted} in xp-generic {where}: only "
                           "dtype constructors/constants and "
                           "np.errstate are backend-neutral; array "
                           "ops must go through xp")
            if bad:
                reported.add(key)
                inner = node
                while isinstance(inner, ast.Attribute):
                    inner = inner.value
                    reported.add((getattr(inner, "lineno", -1),
                                  getattr(inner, "col_offset", -1)))
                yield Finding(self.name, src.rel, node.lineno, bad)
