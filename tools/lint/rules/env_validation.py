"""env-validation: environment reads route through validated ``_env_*``
helpers, and string-enum literals must be members of their registry.

Contract (PR 5/6/8 loud-validation sweeps): a misconfigured
performance knob must fail at startup naming the variable — a campaign
quietly running unsharded (junk ``REPRO_DEVICES`` swallowed) or
single-worker (junk ``REPRO_WORKERS``) is the worst failure mode.
Two checks:

  * every ``os.environ.get``/``os.environ[...]``/``os.getenv`` *read*
    must sit inside an ``_env``-prefixed helper (the
    ``device_config._env_int`` idiom: validate, raise ``ValueError``
    naming the variable) — except free-form pass-through variables
    (``XLA_FLAGS``/``JAX_PLATFORM_NAME``) that downstream consumers
    validate themselves.  Writes are configuration, not reads, and
    stay legal.
  * string literals passed as registry-typed keyword arguments
    (``engine=``, ``select_backend=``, ``demand_profile=``,
    ``scenario=``) must be members of the registry that validates
    them at runtime — the registries are re-parsed from their
    defining modules at lint time, so the lint can never drift from
    the code (``ENGINES`` in experiments/spec.py, ``BACKENDS`` in
    core/simulator_vec.py, ``DEMAND_PROFILES`` in core/simulator.py,
    ``SCENARIOS`` keys + the ``faults@<float>`` family in
    scenarios/scenario.py).
"""
from __future__ import annotations

import ast
from typing import Dict, Optional, Tuple

from tools.lint.core import (Context, Finding, ImportMap, Rule,
                             Source, register)

#: env vars whose values are free-form strings validated downstream
FREEFORM_ENV = {"XLA_FLAGS", "JAX_PLATFORM_NAME", "PYTHONPATH", "CI"}

#: registry-typed keyword arguments -> (defining module, extractor)
REGISTRY_SOURCES = {
    "engine": ("src/repro/experiments/spec.py", "ENGINES"),
    "select_backend": ("src/repro/core/simulator_vec.py", "BACKENDS"),
    "demand_profile": ("src/repro/core/simulator.py",
                       "DEMAND_PROFILES"),
    "scenario": ("src/repro/scenarios/scenario.py", "SCENARIOS"),
}


def _enclosing_functions(tree: ast.Module) -> Dict[int, str]:
    """lineno -> name of the innermost function containing it."""
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            spans.append((node.lineno, node.end_lineno or node.lineno,
                          node.name))
    out: Dict[int, str] = {}
    for lo, hi, name in sorted(spans, key=lambda s: s[1] - s[0],
                               reverse=True):
        for ln in range(lo, hi + 1):
            out[ln] = name            # innermost (smallest span) wins
    return out


def _load_registry(ctx: Context, rel: str,
                   symbol: str) -> Optional[Tuple[str, ...]]:
    """Parse ``symbol``'s literal members out of a defining module.

    Returns None when the module (or symbol) is absent — e.g. lint
    runs rooted at a fixture tree — in which case the enum check is
    skipped rather than guessed at.
    """
    path = ctx.root / rel
    if not path.exists():
        return None
    try:
        tree = ctx.source(path).tree
    except SyntaxError:
        return None
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == symbol
                   for t in node.targets):
            continue
        v = node.value
        if isinstance(v, (ast.Tuple, ast.List, ast.Set)):
            elts = v.elts
        elif isinstance(v, ast.Dict):
            elts = v.keys
        else:
            continue
        members = tuple(e.value for e in elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str))
        if members:
            return members
    return None


def _valid_scenario(value: str, members: Tuple[str, ...]) -> bool:
    if value in members:
        return True
    if value.startswith("faults@"):
        try:
            float(value[len("faults@"):])
            return True
        except ValueError:
            return False
    return False


@register
class EnvValidationRule(Rule):
    name = "env-validation"
    contract = ("os.environ reads go through validated _env_* "
                "helpers; registry-typed string literals must be "
                "registry members")

    def check_source(self, src: Source, ctx: Context):
        imap = ImportMap(src.tree)
        owners = _enclosing_functions(src.tree)

        for node in ast.walk(src.tree):
            # --- raw environment reads -------------------------------
            read = self._env_read(node, imap)
            if read is not None:
                varname = read
                fn = owners.get(node.lineno, "")
                if fn.startswith("_env"):
                    continue              # inside a validating helper
                if varname in FREEFORM_ENV:
                    continue
                shown = varname or "<dynamic>"
                yield Finding(
                    self.name, src.rel, node.lineno,
                    f"raw environment read of {shown} outside an "
                    "_env_* helper: route through a validating "
                    "helper (device_config._env_int idiom) so junk "
                    "values raise a ValueError naming the variable")
                continue

            # --- registry-typed string-literal kwargs ----------------
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg not in REGISTRY_SOURCES:
                        continue
                    if not (isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, str)):
                        continue
                    rel_mod, symbol = REGISTRY_SOURCES[kw.arg]
                    members = _load_registry(ctx, rel_mod, symbol)
                    if members is None:
                        continue
                    value = kw.value.value
                    ok = (_valid_scenario(value, members)
                          if kw.arg == "scenario"
                          else value in members)
                    if not ok:
                        yield Finding(
                            self.name, src.rel, kw.value.lineno,
                            f"{kw.arg}={value!r} is not a member of "
                            f"{symbol} in {rel_mod} "
                            f"(members: {sorted(members)}"
                            + (", or 'faults@<float>'"
                               if kw.arg == "scenario" else "")
                            + ") — this call would raise at runtime")

    @staticmethod
    def _env_read(node: ast.AST, imap: ImportMap) -> Optional[str]:
        """Env-var name for a read node ('' when dynamic), else None."""
        if isinstance(node, ast.Call):
            dotted = imap.resolve(node.func)
            if dotted in ("os.environ.get", "os.getenv"):
                if node.args and isinstance(node.args[0], ast.Constant):
                    return str(node.args[0].value)
                return ""
        if isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, ast.Load):
            if imap.resolve(node.value) == "os.environ":
                sl = node.slice
                if isinstance(sl, ast.Constant):
                    return str(sl.value)
                return ""
        return None
