"""doc-link + module-docstring: the docs checks, migrated from the
standalone ``tools/check_docs.py`` into the lint framework (PR 2
introduced them as a separate CI job; this PR gives CI a single
analysis entry point).

  * **doc-link** — every relative link target in a linted ``*.md``
    file resolves to an existing file/directory (anchors stripped,
    http(s)/mailto ignored).  A broken intra-repo link means a doc
    promises something the tree no longer has.
  * **module-docstring** — every public module in the documented
    package dirs carries a real module docstring (>= 40 chars): the
    architecture docs promise each core/experiments module names the
    paper section it implements, and the later layers (serving,
    scenarios, runtime, launch) adopted the same contract.
"""
from __future__ import annotations

import ast
import re

from tools.lint.core import (Context, Finding, Rule, Source, in_zone,
                             register)

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

DOCSTRING_ZONES = (
    "src/repro/core/",
    "src/repro/experiments/",
    "src/repro/serving/",
    "src/repro/scenarios/",
    "src/repro/runtime/",
    "src/repro/launch/",
)
MIN_DOCSTRING_CHARS = 40


@register
class DocLinkRule(Rule):
    name = "doc-link"
    contract = "relative markdown links resolve inside the repo"
    suffixes = (".md",)

    def check_source(self, src: Source, ctx: Context):
        for i, line in enumerate(src.lines, start=1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://",
                                      "mailto:", "#")):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                if not (src.path.parent / path).resolve().exists():
                    yield Finding(self.name, src.rel, i,
                                  f"broken link -> {target}")


@register
class ModuleDocstringRule(Rule):
    name = "module-docstring"
    contract = ("public modules in documented package dirs carry a "
                f">= {MIN_DOCSTRING_CHARS}-char module docstring")

    def check_source(self, src: Source, ctx: Context):
        if not in_zone(src.rel, DOCSTRING_ZONES):
            return
        name = src.path.name
        if name.startswith("_") and name != "__init__.py":
            return                         # private helpers exempt
        doc = ast.get_docstring(src.tree)
        if not doc or len(doc) < MIN_DOCSTRING_CHARS:
            yield Finding(
                self.name, src.rel, 1,
                "missing or too-short module docstring "
                f"(< {MIN_DOCSTRING_CHARS} chars): say what paper "
                "section / layer contract this module implements")
