"""jit-purity: ``lax.while_loop``/``fori_loop`` body (and cond)
functions stay pure traced code.

Contract (PR 4): the entire lockstep step compiles as one pure
``(carry) -> (carry)`` function; host constructs inside it either fail
at trace time (Python branching on a tracer) or — worse — trace
"successfully" into silent wrongness (a host ``np`` op constant-folds
one batch's values into the compiled graph).  This rule flags, inside
detected loop-body scopes:

  * Python ``if``/``while``/``assert`` whose test references a traced
    name (a loop-body parameter, or any local assigned from one —
    branching on *closure* statics like ``_build_run``'s
    ``use_banks``/``preempt`` is legal staging and not flagged);
  * ``float()``/``int()``/``bool()`` coercions of traced names and
    ``.item()``/``.tolist()`` calls — host round-trips;
  * ``np.*`` calls taking traced arguments (dtype constants like
    ``np.uint64(33)`` with literal args are legal weak-typed
    scalars);
  * host-callback escapes (``jax.debug.callback``,
    ``jax.pure_callback``, ``io_callback``, ``host_callback``) —
    flagged unconditionally; pragma one if it is truly intended.

Bodies are resolved statically: a ``Name``, a ``functools.partial``
over a name (pre-bound arguments count as traced too — they are loop
operands), or an inline ``lambda``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from tools.lint.core import (Context, Finding, ImportMap, Rule,
                             Source, register)

LOOP_CALLS = {"jax.lax.while_loop", "lax.while_loop",
              "jax.lax.fori_loop", "lax.fori_loop"}

CALLBACKS = {"jax.debug.callback", "jax.pure_callback",
             "jax.experimental.io_callback", "io_callback",
             "jax.experimental.host_callback"}

COERCIONS = {"float", "int", "bool", "complex"}

HOST_METHODS = {"item", "tolist"}


def _function_defs(tree: ast.Module) -> Dict[str, List[ast.AST]]:
    out: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(node)
    return out


def _body_functions(call: ast.Call, dotted: str, imap: ImportMap,
                    defs: Dict[str, List[ast.AST]]):
    """The traced-function arguments of one loop call: (cond, body)
    for while_loop, body for fori_loop."""
    idx = (0, 1) if dotted.endswith("while_loop") else (2,)
    for i in idx:
        if i >= len(call.args):
            continue
        arg = call.args[i]
        if isinstance(arg, ast.Lambda):
            yield arg
        elif isinstance(arg, ast.Name):
            yield from defs.get(arg.id, [])
        elif isinstance(arg, ast.Call) and \
                imap.resolve(arg.func) in ("functools.partial",
                                           "partial"):
            target = arg.args[0] if arg.args else None
            if isinstance(target, ast.Name):
                yield from defs.get(target.id, [])


def _params(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _tainted_names(fn) -> Set[str]:
    """Params plus every local transitively assigned from one."""
    tainted = set(_params(fn))
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            targets: List[ast.AST] = []
            value = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
                value = node.value
            elif isinstance(node, ast.For):
                targets, value = [node.target], node.iter
            elif isinstance(node, ast.withitem) and node.optional_vars:
                targets = [node.optional_vars]
                value = node.context_expr
            if value is None:
                continue
            if not any(n in tainted for n in _names(value)):
                continue
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name) and n.id not in tainted:
                        tainted.add(n.id)
                        changed = True
    return tainted


def _names(node: ast.AST) -> Iterable[str]:
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            yield n.id


@register
class JitPurityRule(Rule):
    name = "jit-purity"
    contract = ("lax loop bodies: no python branching on traced "
                "values, host coercions, traced np calls, or host "
                "callbacks")

    def check_source(self, src: Source, ctx: Context):
        imap = ImportMap(src.tree)
        defs = _function_defs(src.tree)
        seen: Set[int] = set()
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = imap.resolve(node.func)
            if dotted not in LOOP_CALLS:
                continue
            for fn in _body_functions(node, dotted, imap, defs):
                if id(fn) in seen:
                    continue
                seen.add(id(fn))
                yield from self._check_body(src, fn, imap)

    def _check_body(self, src: Source, fn, imap: ImportMap):
        tainted = _tainted_names(fn)
        label = getattr(fn, "name", "<lambda>")
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                hits = sorted(set(_names(node.test)) & tainted)
                if hits:
                    yield Finding(
                        self.name, src.rel, node.lineno,
                        f"python {type(node).__name__.lower()} on "
                        f"traced value(s) {hits} inside loop body "
                        f"{label!r}: use jnp.where / lax.cond — "
                        "python control flow cannot branch on "
                        "tracers")
            elif isinstance(node, ast.Assert):
                hits = sorted(set(_names(node.test)) & tainted)
                if hits:
                    yield Finding(
                        self.name, src.rel, node.lineno,
                        f"assert on traced value(s) {hits} inside "
                        f"loop body {label!r}: use "
                        "checkify/error codes — asserts read tracer "
                        "truthiness on the host")
            elif isinstance(node, ast.Call):
                yield from self._check_call(src, node, imap, tainted,
                                            label)

    def _check_call(self, src: Source, node: ast.Call,
                    imap: ImportMap, tainted, label):
        fnref = node.func
        arg_names = set()
        for a in list(node.args) + [k.value for k in node.keywords]:
            arg_names |= set(_names(a))
        traced_args = sorted(arg_names & tainted)

        if isinstance(fnref, ast.Name) and fnref.id in COERCIONS \
                and traced_args:
            yield Finding(
                self.name, src.rel, node.lineno,
                f"{fnref.id}() coerces traced value(s) "
                f"{traced_args} to a host scalar inside loop body "
                f"{label!r}: keep it as a traced array "
                "(astype/jnp ops)")
            return
        if isinstance(fnref, ast.Attribute) and \
                fnref.attr in HOST_METHODS:
            yield Finding(
                self.name, src.rel, node.lineno,
                f".{fnref.attr}() inside loop body {label!r} "
                "round-trips a tracer to the host")
            return
        dotted = imap.resolve(fnref)
        if dotted is None:
            return
        if dotted in CALLBACKS or dotted.startswith(
                "jax.experimental.host_callback."):
            yield Finding(
                self.name, src.rel, node.lineno,
                f"host callback {dotted} inside loop body {label!r}: "
                "the compiled lockstep must not escape to the host "
                "per step (pragma this line if truly intended)")
        elif dotted.startswith("numpy.") and traced_args:
            yield Finding(
                self.name, src.rel, node.lineno,
                f"{dotted}(...) applied to traced value(s) "
                f"{traced_args} inside loop body {label!r}: host "
                "numpy ops constant-fold or break tracing — use the "
                "jnp equivalent")
