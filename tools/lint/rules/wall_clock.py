"""no-wall-clock: pure simulation/serving paths never *call* the wall
clock — clocks are injected.

Contract (PR 7): every timestamp in ``core/``, ``serving/``,
``scenarios/`` and ``experiments/`` flows through an injected zero-arg
clock callable (``core.serving.MESCServer(clock=...)``,
``serving.clock.VirtualClock``); ``time.monotonic`` may appear as a
*default value* or be stored/passed as an object, but calling
``time.time()``/``time.monotonic()``/``datetime.now()`` inline makes
the result time-dependent and kills byte-reproducibility (the fig12
byte-identical-replay CI gate exists because of exactly this class of
bug).

Only ``ast.Call`` nodes are flagged: references used as injectable
defaults stay legal, which is precisely the injection contract.
"""
from __future__ import annotations

import ast

from tools.lint.core import (Context, Finding, ImportMap, Rule,
                             Source, in_zone, register)

#: injected-clock zones (launch/, checkpointing/ and benchmarks are
#: host-side tools that legitimately measure wall time)
PURE_ZONES = (
    "src/repro/core/",
    "src/repro/serving/",
    "src/repro/scenarios/",
    "src/repro/experiments/",
)

BANNED_CALLS = {
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}


@register
class WallClockRule(Rule):
    name = "no-wall-clock"
    contract = ("pure sim/serving paths call injected clocks only; "
                "wall-clock reads are host-tool territory")

    def check_source(self, src: Source, ctx: Context):
        if not in_zone(src.rel, PURE_ZONES):
            return
        imap = ImportMap(src.tree)
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = imap.resolve(node.func)
            if dotted in BANNED_CALLS:
                yield Finding(
                    self.name, src.rel, node.lineno,
                    f"{dotted}() called in pure path {src.rel!r}: "
                    "inject a clock callable (PR 7 contract — "
                    "referencing the function as a default is fine, "
                    "calling it inline is not)")
