"""salt-drift: every ``*_SEMANTICS_VERSION`` cache salt is pinned
against a normalized content hash of its engine's semantic surface.

Contract (PRs 3-8): campaign cache keys embed a per-engine semantics
version, so editing an engine without bumping its salt silently serves
stale cached results — byte-compatible, wrong, and invisible until a
figure disagrees with a fresh run.  Keeping salts honest was manual
chore-work in PRs 5, 6 and 8 (every jit carry change meant "bump
``JIT_SIM_SEMANTICS_VERSION``, regenerate
``tests/data/engine_point_hashes.json``"); this rule mechanizes it.

``tools/lint/salts.json`` pins, per salt:

  * ``defined_in`` + ``value`` — the constant and where it lives;
  * ``surface`` — the files whose semantics the salt covers (engine
    modules plus the shared scenario/CRN code compiled into them);
  * ``surface_hash`` — sha256 over the *normalized* token streams of
    the surface files.

Normalization (:func:`normalized_fingerprint`) strips comments, blank
lines and docstrings via ``tokenize`` + AST docstring positions, so
formatting/comment/doc edits never fire the rule, while any token the
interpreter sees does.  Workflow on a genuine semantic edit: bump the
salt(s) whose engines changed, regenerate
``tests/data/engine_point_hashes.json`` if spec hashes moved, then
``python -m tools.lint --update-salts`` to re-pin; for a provably
semantics-neutral refactor, ``--update-salts`` alone re-pins without
a bump (a conscious, diff-visible decision — which is the point).
"""
from __future__ import annotations

import ast
import hashlib
import io
import json
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional

from tools.lint.core import (Context, Finding, LintConfigError, Rule,
                             register)

SALTS_REL = Path("tools/lint/salts.json")
SALTS_VERSION = 1

_SKIP_TOKENS = {tokenize.COMMENT, tokenize.NL, tokenize.ENCODING,
                tokenize.ENDMARKER}


def _docstring_positions(tree: ast.Module):
    """(lineno, col) of every docstring constant, to drop from the
    token stream (docstrings are semantics-neutral)."""
    out = set()
    nodes = [tree] + [n for n in ast.walk(tree)
                      if isinstance(n, (ast.FunctionDef,
                                        ast.AsyncFunctionDef,
                                        ast.ClassDef))]
    for n in nodes:
        body = getattr(n, "body", [])
        if body and isinstance(body[0], ast.Expr) and \
                isinstance(body[0].value, ast.Constant) and \
                isinstance(body[0].value.value, str):
            c = body[0].value
            out.add((c.lineno, c.col_offset))
    return out


def normalized_fingerprint(text: str) -> str:
    """sha256 over the comment-/docstring-/formatting-insensitive
    token stream of one Python source text.

    Token *names* (not version-dependent numeric codes) key the
    stream, so the hash is stable across CPython minor versions.
    """
    doc_pos = _docstring_positions(ast.parse(text))
    h = hashlib.sha256()
    for tok in tokenize.generate_tokens(io.StringIO(text).readline):
        if tok.type in _SKIP_TOKENS:
            continue
        if tok.type == tokenize.STRING and tok.start in doc_pos:
            continue
        h.update(tokenize.tok_name[tok.type].encode())
        h.update(b"\x1f")
        h.update(tok.string.encode())
        h.update(b"\x1e")
    return h.hexdigest()


def surface_hash(root: Path, files: Iterable[str]) -> str:
    """Combined normalized hash of a salt's semantic surface."""
    h = hashlib.sha256()
    for rel in sorted(files):
        h.update(rel.encode())
        h.update(b"\x00")
        h.update(normalized_fingerprint(
            (root / rel).read_text(encoding="utf-8")).encode())
        h.update(b"\x00")
    return h.hexdigest()


def load_salts(root: Path) -> Optional[Dict]:
    path = root / SALTS_REL
    if not path.exists():
        return None
    data = json.loads(path.read_text(encoding="utf-8"))
    if data.get("version") != SALTS_VERSION:
        raise LintConfigError(
            f"{path}: salts config version {data.get('version')!r} "
            f"!= {SALTS_VERSION}")
    return data


def _find_salt_assignment(tree: ast.Module, name: str):
    """(lineno, int value) of ``NAME = <int>`` at module level."""
    for node in tree.body:
        if isinstance(node, ast.Assign) and \
                any(isinstance(t, ast.Name) and t.id == name
                    for t in node.targets) and \
                isinstance(node.value, ast.Constant) and \
                isinstance(node.value.value, int):
            return node.lineno, node.value.value
    return None


def update_salts(root: Path) -> List[str]:
    """Re-pin every salt's value and surface hash; returns the names
    whose pins changed.  Used by ``python -m tools.lint
    --update-salts``."""
    root = Path(root).resolve()
    data = load_salts(root)
    if data is None:
        raise LintConfigError(f"no salts config at {root / SALTS_REL}")
    changed = []
    for name, pin in sorted(data["salts"].items()):
        tree = ast.parse((root / pin["defined_in"]
                          ).read_text(encoding="utf-8"))
        found = _find_salt_assignment(tree, name)
        if found is None:
            raise LintConfigError(
                f"{pin['defined_in']}: no module-level integer "
                f"assignment for {name}")
        _, value = found
        new_hash = surface_hash(root, pin["surface"])
        if value != pin["value"] or new_hash != pin["surface_hash"]:
            changed.append(name)
        pin["value"] = value
        pin["surface_hash"] = new_hash
    (root / SALTS_REL).write_text(
        json.dumps(data, indent=1, sort_keys=True) + "\n",
        encoding="utf-8")
    return changed


@register
class SaltDriftRule(Rule):
    name = "salt-drift"
    contract = ("*_SEMANTICS_VERSION salts are pinned to a normalized "
                "hash of their engine's semantic surface")

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        data = load_salts(ctx.root)
        if data is None:
            return                        # fixture roots without pins
        for name, pin in sorted(data["salts"].items()):
            defined_in = pin["defined_in"]
            path = ctx.root / defined_in
            if not path.exists():
                yield Finding(self.name, defined_in, 0,
                              f"salt {name} pinned but its defining "
                              "module is gone; update "
                              "tools/lint/salts.json")
                continue
            try:
                found = _find_salt_assignment(ctx.source(path).tree,
                                              name)
            except SyntaxError:
                continue                  # parse-error reported already
            if found is None:
                yield Finding(self.name, defined_in, 0,
                              f"salt {name} not found as a module-"
                              "level integer assignment; update "
                              "tools/lint/salts.json")
                continue
            lineno, value = found
            missing = [f for f in pin["surface"]
                       if not (ctx.root / f).exists()]
            if missing:
                yield Finding(self.name, defined_in, lineno,
                              f"salt {name}: surface file(s) "
                              f"{missing} missing; update "
                              "tools/lint/salts.json")
                continue
            actual = surface_hash(ctx.root, pin["surface"])
            if value != pin["value"]:
                yield Finding(
                    self.name, defined_in, lineno,
                    f"{name} = {value} but the pin records "
                    f"{pin['value']}: after bumping a salt, "
                    "regenerate tests/data/engine_point_hashes.json "
                    "(engine cache keys moved) and re-pin with "
                    "`python -m tools.lint --update-salts`")
            elif actual != pin["surface_hash"]:
                yield Finding(
                    self.name, defined_in, lineno,
                    f"semantic surface of {name} changed without a "
                    f"salt bump (files: {', '.join(pin['surface'])})"
                    ": bump the salt and regenerate "
                    "tests/data/engine_point_hashes.json, or — only "
                    "for a semantics-neutral refactor — re-pin via "
                    "`python -m tools.lint --update-salts`")
