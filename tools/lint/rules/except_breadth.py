"""except-breadth: no new bare/broad exception handlers that swallow.

Contract (PR 5's loud-error sweep, mechanized by this PR's satellite):
``except Exception`` / bare ``except`` hides real failures — the
retry-ladder exhaustion bug returned saturated-table *metrics* instead
of an error for two PRs because a broad handler ate the signal.  A
broad handler is legal only when it

  * re-raises (a bare ``raise`` anywhere in the handler body — the
    cleanup-then-propagate idiom swallows nothing), or
  * carries a justifying ``# repro-lint: disable=except-breadth``
    pragma naming why the boundary must be broad (CLI harness
    boundaries that print-and-continue).

Everything else must name the exception types it expects.
"""
from __future__ import annotations

import ast

from tools.lint.core import Context, Finding, Rule, Source, register

BROAD = {"Exception", "BaseException"}


def _is_broad(type_node) -> bool:
    if type_node is None:
        return True                               # bare except
    if isinstance(type_node, ast.Name):
        return type_node.id in BROAD
    if isinstance(type_node, ast.Tuple):
        return any(_is_broad(e) for e in type_node.elts)
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) and n.exc is None
               for n in ast.walk(handler))


@register
class ExceptBreadthRule(Rule):
    name = "except-breadth"
    contract = ("broad except handlers must re-raise or carry a "
                "justifying pragma; otherwise name the exceptions")

    def check_source(self, src: Source, ctx: Context):
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node.type):
                continue
            if _reraises(node):
                continue
            what = ("bare except" if node.type is None else
                    f"except {ast.unparse(node.type)}")
            yield Finding(
                self.name, src.rel, node.lineno,
                f"{what} swallows errors silently: narrow to the "
                "specific exception types this site expects (and log "
                "the swallowed error loudly), or justify with "
                "# repro-lint: disable=except-breadth")
