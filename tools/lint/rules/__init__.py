"""Rule modules register themselves into ``tools.lint.core.RULES`` at
import time; importing this package activates the full registry.

The IR-level ``ir-*`` family lives in ``tools.graphlint.rules`` and
registers here too (non-default, so the stdlib-only lint job never
pays for it); the guard keeps the AST rules usable when this package
is vendored without its sibling."""
from tools.lint.rules import (docs, env_validation, except_breadth,  # noqa: F401
                              host_rng, jit_purity, salt_drift,
                              wall_clock, xp_generic)

try:
    from tools.graphlint import rules as _ir_rules  # noqa: F401
except ImportError:                  # vendored without tools/graphlint
    pass
