"""Rule modules register themselves into ``tools.lint.core.RULES`` at
import time; importing this package activates the full registry."""
from tools.lint.rules import (docs, env_validation, except_breadth,  # noqa: F401
                              host_rng, jit_purity, salt_drift,
                              wall_clock, xp_generic)
