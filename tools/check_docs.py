"""Docs lint for CI: broken intra-repo markdown links + missing module
docstrings.

Checks (both fail the build):

1. every relative link target in any tracked ``*.md`` file resolves to
   an existing file/directory (anchors stripped; http(s)/mailto links
   are ignored);
2. every public module under ``src/repro/core/`` and
   ``src/repro/experiments/`` carries a real module docstring (the
   architecture docs promise each names the paper section it
   implements).

Run from the repo root: ``python tools/check_docs.py``.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "results", "__pycache__", ".pytest_cache",
             "build", "dist"}
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
DOCSTRING_DIRS = ("src/repro/core", "src/repro/experiments")
MIN_DOCSTRING_CHARS = 40


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def check_links() -> list[str]:
    errors = []
    for md in md_files():
        for target in LINK_RE.findall(md.read_text(encoding="utf-8")):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstrings() -> list[str]:
    errors = []
    for d in DOCSTRING_DIRS:
        for py in sorted((ROOT / d).glob("*.py")):
            if py.name.startswith("_") and py.name != "__init__.py":
                continue                      # private helpers exempt
            tree = ast.parse(py.read_text(encoding="utf-8"))
            doc = ast.get_docstring(tree)
            if not doc or len(doc) < MIN_DOCSTRING_CHARS:
                errors.append(
                    f"{py.relative_to(ROOT)}: missing or too-short "
                    f"module docstring (< {MIN_DOCSTRING_CHARS} chars)")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        print(f"{len(errors)} docs problem(s)")
        return 1
    n_md = sum(1 for _ in md_files())
    print(f"docs OK: {n_md} markdown files, all links resolve, "
          f"all public core/experiments modules documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
