"""Deprecated shim: the docs checks live in the lint framework now.

The standalone checker this file used to contain was migrated into
``tools/lint`` as the ``doc-link`` and ``module-docstring`` rules (with
wider docstring coverage: serving/, scenarios/, runtime/ and launch/
joined core/ and experiments/).  This entry point survives so older CI
configs and habits keep working — it simply runs those two rules over
the default lint surface:

    python tools/check_docs.py
    # equivalent to:
    python -m tools.lint --rules doc-link,module-docstring

Prefer ``python -m tools.lint`` (all rules) going forward.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from tools.lint.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--rules", "doc-link,module-docstring"]))
