"""Deprecated shim: the docs checks live in the lint framework now.

The standalone checker this file used to contain was migrated into
``tools/lint`` as the ``doc-link`` and ``module-docstring`` rules (with
wider docstring coverage: serving/, scenarios/, runtime/ and launch/
joined core/ and experiments/).  This entry point survives for one
release so older CI configs and habits keep working — it emits a
:class:`DeprecationWarning` and then simply runs those two rules over
the default lint surface:

    python tools/check_docs.py
    # equivalent to:
    python -m tools.lint --rules doc-link,module-docstring

Prefer ``python -m tools.lint`` (all rules) going forward.
"""
from __future__ import annotations

import sys
import warnings
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from tools.lint.__main__ import main as lint_main  # noqa: E402


def main(argv=None) -> int:
    warnings.warn(
        "tools/check_docs.py is deprecated and will be removed; use "
        "python -m tools.lint (or --rules doc-link,module-docstring "
        "for exactly the old checks)",
        DeprecationWarning, stacklevel=2)
    return lint_main(["--rules", "doc-link,module-docstring"]
                     + list(argv or []))


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
