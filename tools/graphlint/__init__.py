"""graph-lint: jaxpr/IR-level contract checker for the engines.

Where ``tools/lint`` (repro-lint) mechanizes the repo's *source-level*
contracts by AST inspection, this package mechanizes the
*compiled-graph* contracts the MESC overhead claims rest on: the
lockstep while-body kernel budget, the dtype-homogeneous grouped
carry, scenario neutrality of disabled fault components, buffer
donation, CRN purity at the primitive level, and the O(1) retrace
surface.  The pinned values live in the committed manifest
``tools/graphlint/budgets.json``; drift is a lint finding, a
conscious change is a manifest repin (``--update-budgets``), exactly
mirroring the salt-drift workflow.

Two entry points, one rule family:

* ``python -m tools.graphlint`` — the dedicated front-end (traces,
  compares, exits 0/1/2);
* ``python -m tools.lint --rules ir-budget-drift,...`` — the same
  rules through the repro-lint registry (they are non-default there,
  keeping the stdlib-only lint job jax-free).

``benchmarks/perf_sim.py`` sources its ``xla_kernels`` numbers from
the same manifest via :func:`tools.graphlint.budgets.kernel_budget`.
See docs/linting.md for the rule catalog.
"""
from tools.graphlint.budgets import (BUDGETS_REL, CANONICAL_CASE,  # noqa: F401
                                     NEUTRAL_CASE, kernel_budget,
                                     load_budgets, update_budgets)

#: the rule family ``python -m tools.graphlint`` runs, in registry
#: name order
IR_RULES = ("ir-budget-drift", "ir-donation", "ir-dtype-discipline",
            "ir-graph-purity", "ir-retrace-surface")
