"""Budget manifests: the committed IR contract of the engines.

``tools/graphlint/budgets.json`` pins, per representative engine
configuration, what the compiler is allowed to build: while-body
kernel count, primitive histogram, loop-carry inventory, donation
evidence, dtype counters, the span planner's retrace surface, and the
serving stack's zero-compilation contract.  The workflow mirrors the
salt-drift rule exactly:

* ``python -m tools.graphlint`` re-traces the manifest's cases and
  fails on any divergence from the pinned budgets (rule family
  ``ir-*``, anchored at the manifest file);
* a *conscious* graph change is repinned with
  ``python -m tools.graphlint --update-budgets`` — the manifest diff
  then documents the regression or improvement in review, the same
  way a salt bump documents a semantics change.

The manifest is also the single source ``benchmarks/perf_sim.py``
logs ``xla_kernels`` / ``xla_kernels_neutral_scenario`` from
(:func:`kernel_budget`), so the perf trajectory in ``BENCH_sim.json``
and the lint gate can never quote different numbers.

Tracing always runs against the real checkout (see
``tools/graphlint/trace.py``); a ``--root`` only selects which
manifest file is read — that is what lets tests exercise tampered
manifests on throwaway trees while sharing one set of (expensive)
compiles through :func:`live_report`'s memo.
"""
from __future__ import annotations

import copy
import json
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from tools.graphlint import trace
from tools.lint.core import LintConfigError

BUDGETS_REL = Path("tools/graphlint/budgets.json")
BUDGETS_VERSION = 1

#: the case perf_sim's ``xla_kernels`` field is sourced from, and the
#: neutral-scenario case that must compile to the identical graph
CANONICAL_CASE = "jit-mesc-sampled"
NEUTRAL_CASE = "jit-mesc-neutral"

#: live-only diagnostics never pinned in the manifest (purity is an
#: absolute contract — an empty dict is the only acceptable value, so
#: pinning it would just invite repinning a violation)
UNPINNED_FIELDS = ("banned_primitives",)

#: the manifest skeleton ``--update-budgets`` starts from when no
#: manifest exists yet: the canonical corpus shape and the case
#: configurations worth pinning.  One compile per distinct graph —
#: the neutral case shares the canonical compile via the engine's
#: ``_compiled_run`` memo.
DEFAULT_MANIFEST: Dict[str, Any] = {
    "version": BUDGETS_VERSION,
    "spec": {
        # fig8_corpus(utils, n_seeds, n_tasks): 64 points — the
        # production _STREAM_CHUNK dispatch rectangle — at the
        # default interrupt-table width
        "utils": [0.7, 0.9], "n_seeds": 32, "n_tasks": 10,
        "duration": 2.0e6, "overrun_prob": 0.3, "cf": 2.0,
        "table_width": 64, "chunk": 64,
    },
    "cases": {
        "jit-mesc-sampled": {
            "config": {"policy": "mesc", "demand_profile": "sampled",
                       "scenario": None, "devices": 1}},
        "jit-mesc-neutral": {
            "config": {"policy": "mesc", "demand_profile": "sampled",
                       "scenario": "faults@0", "devices": 1},
            "equals": "jit-mesc-sampled"},
        "jit-mesc-active": {
            "config": {"policy": "mesc", "demand_profile": "sampled",
                       "scenario": "faults@1", "devices": 1}},
        "jit-mesc-nominal": {
            "config": {"policy": "mesc", "demand_profile": "nominal",
                       "scenario": None, "devices": 1}},
        "jit-np-sampled": {
            "config": {"policy": "non_preemptive",
                       "demand_profile": "sampled",
                       "scenario": None, "devices": 1}},
        "jit-mesc-sampled-d2": {
            "config": {"policy": "mesc", "demand_profile": "sampled",
                       "scenario": None, "devices": 2}},
        "serving-virtual": {
            "config": {"engine": "serving"}},
    },
}

#: pseudo-case name selecting the retrace-surface computation in
#: ``--cases`` filters
RETRACE_CASE = "retrace"

_case_filter: Optional[frozenset] = None

#: (spec+configs key) -> live report; budgets-comparison tests all
#: share the handful of real compiles behind one report
_live_memo: Dict[str, Dict[str, Any]] = {}


def set_case_filter(names: Optional[Iterable[str]]) -> None:
    """Restrict which manifest cases the rules re-trace (None = all).
    CLI ``--cases`` plumbing; rules read it via :func:`case_filter`."""
    global _case_filter
    _case_filter = None if names is None else frozenset(names)


def case_filter() -> Optional[frozenset]:
    return _case_filter


def budgets_path(root: Optional[Path] = None) -> Path:
    return Path(root or trace.REPO_ROOT) / BUDGETS_REL


def load_budgets(root: Optional[Path] = None) -> Optional[Dict]:
    """The committed manifest under ``root``, or None when absent
    (rules stay silent on manifest-less trees — foreign checkouts
    running ``--rules ir-*`` should not explode)."""
    path = budgets_path(root)
    if not path.exists():
        return None
    data = json.loads(path.read_text(encoding="utf-8"))
    if data.get("version") != BUDGETS_VERSION:
        raise LintConfigError(
            f"{path}: budgets version {data.get('version')!r} != "
            f"{BUDGETS_VERSION}; regenerate with "
            "python -m tools.graphlint --update-budgets")
    return data


def _selected(manifest: Dict,
              only: Optional[Iterable[str]]) -> List[str]:
    names = list(manifest.get("cases", {}))
    if only is not None:
        wanted = set(only)
        unknown = sorted(wanted - set(names) - {RETRACE_CASE})
        if unknown:
            raise LintConfigError(
                f"unknown budget case(s) {unknown}; manifest has "
                f"{sorted(names)} (plus '{RETRACE_CASE}')")
        names = [n for n in names if n in wanted]
    # serving first: its zero-compilation probe is only measurable
    # before any engine trace initializes the XLA backend
    return sorted(names,
                  key=lambda n: (_engine(manifest, n) != "serving", n))


def _engine(manifest: Dict, name: str) -> str:
    return manifest["cases"][name]["config"].get("engine", "jit")


def _memo_key(manifest: Dict, names: Sequence[str],
              with_retrace: bool) -> str:
    return json.dumps(
        {"spec": manifest["spec"], "retrace": with_retrace,
         "cases": {n: manifest["cases"][n]["config"] for n in names}},
        sort_keys=True)


def live_report(manifest: Dict,
                only: Optional[Iterable[str]] = None) -> Dict[str, Any]:
    """Re-trace the manifest's cases and return
    ``{"cases": {name: live-budget}, "retrace": {...}}``.

    Memoized on the (spec, case-config) content, NOT the manifest
    path: tampering with a *pinned value* in a throwaway manifest
    reuses the cached compiles, while changing a config or the corpus
    spec re-traces.  Honors :func:`case_filter` unless ``only`` is
    given explicitly.
    """
    if only is None:
        only = _case_filter
    names = _selected(manifest, only)
    with_retrace = only is None or RETRACE_CASE in set(only)
    key = _memo_key(manifest, names, with_retrace)
    if key not in _live_memo:
        trace.prepare_device_pool(max(
            [int(manifest["cases"][n]["config"].get("devices") or 1)
             for n in names] or [1]))
        cases: Dict[str, Any] = {}
        for name in names:
            cfg = manifest["cases"][name]["config"]
            if cfg.get("engine", "jit") == "serving":
                n = trace.serving_compilations()
                cases[name] = ({} if n is None
                               else {"xla_compilations": n})
            else:
                cases[name] = trace.trace_jit_case(
                    cfg, manifest["spec"])
        report: Dict[str, Any] = {"cases": cases}
        if with_retrace:
            report["retrace"] = trace.retrace_surface(manifest["spec"])
        _live_memo[key] = report
    return _live_memo[key]


# ----------------------------------------------------------------------
# Budget diffing
# ----------------------------------------------------------------------

def flatten(prefix: str, value: Any,
            out: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """``{"carry.dtypes.ev_time": "float64", ...}`` — dotted leaf
    paths, so findings can name the exact drifted field."""
    if out is None:
        out = {}
    if isinstance(value, dict):
        for k in sorted(value):
            flatten(f"{prefix}.{k}" if prefix else str(k),
                    value[k], out)
    else:
        out[prefix] = value
    return out


def diff_budget(pinned: Dict[str, Any], live: Dict[str, Any],
                fields: Optional[Tuple[str, ...]] = None) \
        -> List[Tuple[str, Any, Any]]:
    """(field-path, pinned, live) rows where the two disagree,
    optionally restricted to top-level ``fields`` prefixes.  Live-only
    diagnostics (:data:`UNPINNED_FIELDS`) never count as drift."""
    def keep(d):
        return {k: v for k, v in d.items()
                if k not in UNPINNED_FIELDS
                and (fields is None or k in fields)}
    a, b = flatten("", keep(pinned)), flatten("", keep(live))
    rows: List[Tuple[str, Any, Any]] = []
    for path in sorted(set(a) | set(b)):
        missing = object()
        pa, pb = a.get(path, missing), b.get(path, missing)
        if pa != pb:
            rows.append((path,
                         None if pa is missing else pa,
                         None if pb is missing else pb))
    return rows


def stored_budget(live: Dict[str, Any]) -> Dict[str, Any]:
    """The manifest-persisted subset of one live budget."""
    return {k: copy.deepcopy(v) for k, v in sorted(live.items())
            if k not in UNPINNED_FIELDS}


def update_budgets(root: Optional[Path] = None) -> List[str]:
    """Re-trace everything and (re)write the manifest — the conscious
    repin.  Returns the dotted paths whose pinned values changed.

    The serving probe keeps its previous pin when unmeasurable in
    this process (a jax backend already live); run the repin as a
    fresh ``python -m tools.graphlint --update-budgets`` process for
    an authoritative serving value.
    """
    global _case_filter
    root = Path(root or trace.REPO_ROOT)
    path = budgets_path(root)
    manifest = load_budgets(root) or copy.deepcopy(DEFAULT_MANIFEST)
    saved_filter, _case_filter = _case_filter, None   # repin everything
    try:
        live = live_report(manifest, only=None)
    finally:
        _case_filter = saved_filter
    changed: List[str] = []
    for name, case in manifest["cases"].items():
        old = case.get("budget", {})
        new = stored_budget(live["cases"][name])
        if not new and old:        # unmeasurable serving probe
            new = old
        for fpath, _, _ in diff_budget(old, new):
            changed.append(f"{name}.{fpath}")
        case["budget"] = new
    old_rt = manifest.get("retrace", {})
    for fpath, _, _ in diff_budget(old_rt, live["retrace"]):
        changed.append(f"{RETRACE_CASE}.{fpath}")
    manifest["retrace"] = live["retrace"]
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(manifest, indent=1, sort_keys=True)
                    + "\n", encoding="utf-8")
    return sorted(changed)


def kernel_budget(root: Optional[Path] = None) -> Dict[str, int]:
    """The pinned while-body kernel counts perf_sim logs, verified
    against a live compile before being returned.

    Raises SystemExit (the perf harness's gate idiom) when the
    compiled engine disagrees with the manifest or the neutral
    scenario stops being graph-identical — a perf log must never
    quote a kernel number the current build does not have.
    """
    manifest = load_budgets(root)
    if manifest is None:
        raise SystemExit(
            f"no graph-lint manifest at {budgets_path(root)}; "
            "generate it with python -m tools.graphlint "
            "--update-budgets")
    names = (CANONICAL_CASE, NEUTRAL_CASE)
    live = live_report(manifest, only=names)["cases"]
    out: Dict[str, int] = {}
    for name in names:
        pinned = manifest["cases"][name]["budget"]["while_body_kernels"]
        got = live[name]["while_body_kernels"]
        if got != pinned:
            raise SystemExit(
                f"graph-lint budget drift: {name}.while_body_kernels "
                f"is pinned at {pinned} but the engine compiled {got} "
                "— repin consciously with python -m tools.graphlint "
                "--update-budgets")
        out[name] = pinned
    if out[CANONICAL_CASE] != out[NEUTRAL_CASE]:
        raise SystemExit(
            f"neutral scenario compiled {out[NEUTRAL_CASE]} body "
            f"kernels vs {out[CANONICAL_CASE]} scenario-free — "
            "disabled scenario components must add zero operations")
    return {"xla_kernels": out[CANONICAL_CASE],
            "xla_kernels_neutral_scenario": out[NEUTRAL_CASE]}
