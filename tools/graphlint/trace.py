"""Jaxpr/HLO extraction behind the graph-lint rules.

Everything here answers one question: *what did the compiler actually
build* for a representative engine configuration?  The functions trace
the jit lockstep engine exactly the way production does — same
``_compiled_run`` memo, same table/carry construction, same x64
context — then walk the resulting ClosedJaxpr (recursing into
``while``/``cond``/``scan``/``pjit``/``shard_map`` sub-jaxprs) and the
optimized HLO text to extract the measurable surface the budget
manifests pin:

* while-body kernel count (via the engine's own
  :func:`repro.core.simulator_jit.while_body_kernels` so the manifest
  and ``BENCH_sim.json`` can never disagree about what a kernel is);
* the recursive primitive histogram;
* the loop-carry tensor inventory (count, per-tensor dtype, global
  bytes) against the engine's ``_CARRY_KEYS`` contract;
* buffer-donation evidence (``input_output_alias`` pairs in the HLO
  header, donation-dropped warnings during compile);
* dtype discipline (float32 values anywhere in an x64 graph,
  f64->f32 ``convert_element_type`` demotions);
* CRN purity (callback / transfer / threefry primitives that AST
  linting cannot see through closures);
* the retrace surface of the span planner over the shared corpora;
* the serving virtual path's zero-XLA-compilation contract.

All jax / repro imports are deferred into the functions: importing
this module must stay safe from the stdlib-only lint job (the IR rules
are non-default there; see ``tools/graphlint/rules.py``).  Tracing
always runs against the real checkout this file lives in — a
``--root`` pointing at a throwaway manifest tree changes which
``budgets.json`` is read, never which engine is traced.
"""
from __future__ import annotations

import math
import re
import sys
import warnings
from collections import Counter
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

#: the checkout that owns the traced engines (NOT the lint --root)
REPO_ROOT = Path(__file__).resolve().parents[2]

#: primitives that must never appear in a compiled engine graph: host
#: callbacks and transfers break the pure-loop contract, threefry /
#: random_* primitives break the counter-based CRN contract (every
#: draw must come from the hash-based per-point streams, never from a
#: traced jax.random key)
BANNED_EXACT = frozenset({
    "pure_callback", "io_callback", "debug_callback", "outside_call",
    "infeed", "outfeed", "device_put",
})
BANNED_PREFIXES = ("threefry", "random_")

#: sub-jaxpr-owning primitives whose own eqn is compiler plumbing, not
#: a budgetable operation (their contents are recursed into instead)
WRAPPER_PRIMS = frozenset({"pjit", "closed_call", "custom_jvp_call",
                           "custom_vjp_call", "remat", "shard_map"})


def _ensure_paths() -> None:
    """Make ``repro`` and the test harness importable the way pytest
    arranges them (src/ on the path, tests/ as top-level modules)."""
    for p in (REPO_ROOT / "src", REPO_ROOT / "tests"):
        s = str(p)
        if s not in sys.path:
            sys.path.insert(0, s)


def _harness():
    _ensure_paths()
    import harness
    return harness


def prepare_device_pool(n: int) -> None:
    """Widen the logical host device pool to ``n`` before the first
    backend init, so the manifest's sharded cases can trace.  A no-op
    once XLA is live (pytest's conftest already forces a >= 4-way
    pool; the CLI arrives here first and configures its own)."""
    _ensure_paths()
    from repro.runtime.device_config import (configure_host_devices,
                                             jax_initialized)
    if n > 1 and not jax_initialized():
        configure_host_devices(n)


# ----------------------------------------------------------------------
# Jaxpr walking (raw Jaxpr and ClosedJaxpr handled uniformly)
# ----------------------------------------------------------------------

def _inner(jaxpr_like):
    """The raw eqn-bearing jaxpr: ``while``/``cond``/``pjit`` params
    hold ClosedJaxpr (unwrap ``.jaxpr``), ``shard_map`` params hold
    raw Jaxpr already — normalize both to the raw form, which has
    ``.eqns`` *and* ``.invars``."""
    return getattr(jaxpr_like, "jaxpr", jaxpr_like)


def sub_jaxprs(eqn) -> Iterable[Any]:
    """Every jaxpr-valued param of one eqn (lists included)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else [val]
        for v in vals:
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                if hasattr(_inner(v), "eqns"):
                    yield _inner(v)


def walk_eqns(jaxpr_like) -> Iterable[Any]:
    """Depth-first over every eqn, recursing into sub-jaxprs."""
    for eqn in _inner(jaxpr_like).eqns:
        yield eqn
        for sub in sub_jaxprs(eqn):
            yield from walk_eqns(sub)


def find_while(jaxpr_like):
    """The (first) lockstep ``while`` eqn, at any nesting depth —
    devices=1 traces to pjit->while, sharded to
    pjit->shard_map->while."""
    for eqn in walk_eqns(jaxpr_like):
        if eqn.primitive.name == "while":
            return eqn
    raise ValueError("no while eqn in traced computation — the "
                     "lockstep engine no longer lowers to while_loop?")


def primitive_histogram(jaxpr_like) -> Dict[str, int]:
    """Recursive primitive counts, skipping pure wrapper eqns (their
    names churn across jax versions; their contents are counted)."""
    hist: Counter = Counter()
    for eqn in walk_eqns(jaxpr_like):
        name = eqn.primitive.name
        if name not in WRAPPER_PRIMS:
            hist[name] += 1
    return dict(sorted(hist.items()))


def banned_primitives(jaxpr_like) -> Dict[str, int]:
    """Counts of contract-banned primitives anywhere in the graph."""
    out: Counter = Counter()
    for eqn in walk_eqns(jaxpr_like):
        name = eqn.primitive.name
        if name in BANNED_EXACT or name.startswith(BANNED_PREFIXES):
            out[name] += 1
    return dict(sorted(out.items()))


def dtype_summary(jaxpr_like) -> Dict[str, int]:
    """Dtype-discipline counters over the whole graph.

    ``float32_ops`` counts eqns producing any float32 value — the
    engine runs entirely under x64, so a single f32 aval means XLA
    silently demoted event times somewhere.  ``f64_to_f32_demotions``
    counts explicit f64->f32 ``convert_element_type`` eqns (the int32
    <-> int64 widenings along the step counter are legitimate and are
    pinned by the primitive histogram instead).
    """
    f32_ops = demotions = 0
    for eqn in walk_eqns(jaxpr_like):
        outs = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
        if any(getattr(a, "dtype", None) is not None
               and str(a.dtype) == "float32" for a in outs):
            f32_ops += 1
        if eqn.primitive.name == "convert_element_type":
            src = eqn.invars[0].aval if eqn.invars else None
            dst = outs[0] if outs else None
            if src is not None and dst is not None \
                    and str(getattr(src, "dtype", "")) == "float64" \
                    and str(getattr(dst, "dtype", "")) == "float32":
                demotions += 1
    return {"float32_ops": f32_ops, "f64_to_f32_demotions": demotions}


def carry_summary(while_eqn, devices: int) -> Dict[str, Any]:
    """The loop-carry tensor inventory from the while body's
    signature: the invars after the ``body_nconsts`` closed-over
    constants are exactly the carry, in pytree (sorted-key) order.
    ``total_bytes`` is the global carry footprint (per-shard bytes
    times the device count — every carry tensor shards along the point
    axis, the step counter contributes one lane per device)."""
    _ensure_paths()
    from repro.core.simulator_jit import _CARRY_KEYS
    body = _inner(while_eqn.params["body_jaxpr"])
    n_const = while_eqn.params["body_nconsts"]
    avals = [v.aval for v in body.invars[n_const:]]
    names = sorted(_CARRY_KEYS)
    if len(names) != len(avals):
        names = [f"tensor{i:02d}" for i in range(len(avals))]
    dtypes = {n: str(a.dtype) for n, a in zip(names, avals)}
    per_shard = sum(
        int(a.dtype.itemsize) * int(math.prod(a.shape) if a.shape
                                    else 1)
        for a in avals)
    return {"tensors": len(avals), "dtypes": dtypes,
            "total_bytes": per_shard * max(devices, 1)}


def donation_summary(hlo_text: str,
                     caught: List[warnings.WarningMessage]) \
        -> Dict[str, int]:
    """Donation evidence from one compiled module: ``donated`` counts
    the input/output alias pairs XLA committed to in the module header
    (one per carry leaf when donation worked), ``dropped`` counts
    donation-related warnings jax raised while lowering/compiling
    (nonzero means ``donate_argnums`` silently degraded to a copy)."""
    header = ""
    for line in hlo_text.splitlines():
        if "input_output_alias" in line:
            header = line
            break
    donated = len(re.findall(r"(?:may|must)-alias", header))
    dropped = sum(1 for w in caught
                  if "donat" in str(w.message).lower())
    return {"donated": donated, "dropped": dropped}


# ----------------------------------------------------------------------
# Case tracing
# ----------------------------------------------------------------------

def trace_jit_case(config: Dict[str, Any],
                   spec: Dict[str, Any]) -> Dict[str, Any]:
    """Trace + compile one jit-engine configuration at the manifest's
    canonical corpus shape and return its live budget dict.

    ``config`` mirrors a ``budgets.json`` case entry: ``policy``
    ("mesc" | "non_preemptive"), ``demand_profile``, ``scenario``
    (None or a ``get_scenario`` spec) and ``devices``.  The compile
    goes through the production ``_compiled_run`` memo, so a second
    case that is graph-identical (the neutral-scenario contract) hits
    the same jitted callable.
    """
    _ensure_paths()
    import jax
    from jax.experimental import enable_x64

    import jax.numpy as jnp
    from repro.core import Policy
    from repro.core import simulator_jit as sj
    from repro.scenarios import get_scenario

    h = _harness()
    policy = {"mesc": Policy.mesc,
              "non_preemptive": Policy.non_preemptive}[
        config.get("policy", "mesc")]()
    devices = int(config.get("devices") or 1)
    nominal = config.get("demand_profile", "sampled") == "nominal"
    scenario = get_scenario(config.get("scenario"))
    loop_scen = scenario if scenario is not None \
        and scenario.affects_demand else None   # as simulate_jbatch
    tasksets, seeds = h.fig8_corpus(tuple(spec["utils"]),
                                    int(spec["n_seeds"]),
                                    int(spec["n_tasks"]))
    duration = float(spec["duration"])
    K = int(spec["table_width"])
    b = sj._VecBatch(tasksets, h.LIB, policy,
                     seeds=[int(s) for s in seeds], duration=duration,
                     overrun_prob=float(spec["overrun_prob"]),
                     cf=float(spec["cf"]), scenario=scenario)
    run = sj._compiled_run(policy.use_banks, policy.drop_lo_in_hi,
                           policy.preemption, nominal, sj._PRUNE_STALE,
                           loop_scen, devices)
    with enable_x64():
        tb = sj._tables(b, seeds)
        sc = {"t_sr": jnp.float64(policy.t_sr),
              "overrun_prob": jnp.float64(float(spec["overrun_prob"])),
              "cf": jnp.float64(float(spec["cf"])),
              "duration": jnp.float64(duration),
              "max_steps": jnp.int64(sj._max_steps(b, duration))}
        c0 = sj._carry0(b, seeds, K, devices=devices)
        closed = jax.make_jaxpr(run)(tb, sc, c0)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            hlo = run.lower(tb, sc, c0).compile().as_text()
    while_eqn = find_while(closed.jaxpr)
    budget: Dict[str, Any] = {
        "while_body_kernels": sj.while_body_kernels(hlo),
        "primitive_histogram": primitive_histogram(closed.jaxpr),
        "carry": carry_summary(while_eqn, devices),
        "donation": donation_summary(hlo, list(caught)),
        "banned_primitives": banned_primitives(closed.jaxpr),
    }
    budget.update(dtype_summary(closed.jaxpr))
    return budget


def serving_compilations() -> Optional[int]:
    """XLA backend compilations triggered by one tiny virtual-clock
    serving case.

    The fig12 stack is modelless (virtual clocks, CRN service draws,
    no weights); its only jax traffic is the eager transfer/convert
    executables behind the context-save/restore model
    (``device_put``/``device_get``/``asarray`` — the
    ``step_wise_mvin``/``mvout`` cost accounting).  Those compile a
    fixed handful of trivial kernels; the pinned count is the ceiling
    that catches a jitted model call (or any other real computation)
    sneaking into the virtual path.  Counted via jax's monitoring
    events; returns None when a backend is already live in this
    process — eager kernels are cached process-wide, so only a fresh
    process (the CLI, CI) measures authoritatively."""
    _ensure_paths()
    from repro.runtime.device_config import jax_initialized
    if jax_initialized():
        return None
    try:
        from jax._src import monitoring
    except ImportError:      # jax-internal API drift: unmeasurable
        return None
    count = [0]

    def _listener(event: str, duration: float, **kw) -> None:
        if event == "/jax/core/compile/backend_compile_duration":
            count[0] += 1

    monitoring.register_event_duration_secs_listener(_listener)
    h = _harness()
    case = h.ServingCase(name="graphlint-probe", n_lo=4, n_hi=2)
    h.run_serving_case(case)
    return count[0]


def retrace_surface(spec: Dict[str, Any]) -> Dict[str, Dict[str, int]]:
    """Distinct traced signatures the span planner produces over the
    two shared corpora, per device count.

    A signature is the static shape key jax specializes on —
    (devices, per-device chunk, padded task count, table width).  The
    planner buckets points into devices x chunk rectangles, so the
    signature count must stay O(1) in the corpus size; a count equal
    to ``n_points`` means some axis retraces per point, which is the
    exact anti-precondition for the ROADMAP's mega-batching item.
    Computed statically from ``_plan_spans`` — no compilation.
    """
    _ensure_paths()
    from repro.core.simulator_jit import _plan_spans
    h = _harness()
    K = int(spec["table_width"])
    chunk = int(spec.get("chunk", 64))
    corpora = {
        "fig8": [int(spec["n_tasks"])]
        * (len(spec["utils"]) * int(spec["n_seeds"])),
        "mixed": list(h.MIXED_SIZES),
    }
    out: Dict[str, Dict[str, int]] = {}
    for name, sizes in corpora.items():
        for devices in (1, 2):
            sigs = set()
            for idxs, real, d in _plan_spans(len(sizes), chunk,
                                             devices):
                t_max = max((sizes[i] for i in idxs
                             if i < len(sizes)), default=0)
                sigs.add((d, len(idxs) // max(d, 1), t_max, K))
            out[f"{name}-d{devices}"] = {
                "n_points": len(sizes), "signatures": len(sigs)}
    return out
