"""CLI for graph-lint: ``python -m tools.graphlint``.

Traces the committed manifest's engine cases, compares against the
pinned budgets, and reports through the shared repro-lint machinery
(same finding format, same exit codes: 0 clean, 1 findings, 2 bad
invocation).  ``--update-budgets`` is the conscious-repin step; see
docs/linting.md for the workflow.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.graphlint import IR_RULES, budgets
from tools.lint.core import RULES, LintConfigError, run_lint


def default_root() -> Path:
    """The repo root: this file lives at <root>/tools/graphlint/."""
    return Path(__file__).resolve().parents[2]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.graphlint",
        description="IR-level contract checker: traces the engines' "
                    "compiled graphs and gates them against the "
                    "committed budget manifest "
                    f"({budgets.BUDGETS_REL})")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root whose manifest is checked "
                         "(default: auto-detected; engines are always "
                         "traced from the real checkout)")
    ap.add_argument("--format", choices=("human", "json"),
                    default="human")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of the ir-* family "
                         f"(default: {','.join(IR_RULES)})")
    ap.add_argument("--cases", default=None,
                    help="comma-separated manifest case subset to "
                         "re-trace (plus the pseudo-case "
                         f"'{budgets.RETRACE_CASE}'); default: all")
    ap.add_argument("--update-budgets", action="store_true",
                    help="re-trace everything and repin "
                         f"{budgets.BUDGETS_REL} (the conscious-repin "
                         "step, mirroring --update-salts)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    root = (args.root or default_root()).resolve()
    try:
        import tools.lint.rules  # noqa: F401  (registers ir-* rules)

        if args.list_rules:
            for name in IR_RULES:
                print(f"{name:20s} {RULES[name].contract}")
            return 0

        if args.update_budgets:
            changed = budgets.update_budgets(root)
            print(f"budgets re-pinned: {budgets.budgets_path(root)} "
                  f"({len(changed)} field(s) changed"
                  + (f": {', '.join(changed[:8])}"
                     + (" ..." if len(changed) > 8 else "")
                     if changed else "") + ")")
            return 0

        rule_names = (args.rules.split(",") if args.rules
                      else list(IR_RULES))
        unknown = sorted(set(rule_names) - set(IR_RULES))
        if unknown:
            raise LintConfigError(
                f"unknown ir rule(s) {unknown}; available: "
                f"{list(IR_RULES)}")

        if budgets.load_budgets(root) is None:
            raise LintConfigError(
                f"no manifest at {budgets.budgets_path(root)} — "
                "generate it first with python -m tools.graphlint "
                "--update-budgets")

        budgets.set_case_filter(args.cases.split(",") if args.cases
                                else None)
        try:
            report, _ = run_lint(root, [str(budgets.BUDGETS_REL)],
                                 rule_names=rule_names,
                                 use_baseline=False)
        finally:
            budgets.set_case_filter(None)
    except LintConfigError as e:
        print(f"graph-lint: error: {e}", file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps(report.to_json(), indent=1, sort_keys=True))
        return report.exit_code

    for f in report.findings:
        print(f"{f.location()}: {f.rule}: {f.message}")
    print(f"graph-lint: {len(report.rules_run)} rules over "
          f"{budgets.BUDGETS_REL}: {len(report.findings)} finding(s)")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
