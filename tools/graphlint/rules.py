"""The ``ir-*`` rule family: compiled-graph contracts as lint rules.

These register into the same ``tools.lint.core.RULES`` registry as the
AST rules, so ``python -m tools.lint --rules ir-budget-drift`` works —
but they are **non-default** (``default = False``): the stdlib-only
lint job must never import jax, and an IR trace costs seconds of
compilation.  The dedicated front-end ``python -m tools.graphlint``
selects exactly this family.

Every rule compares the *live* trace of the manifest's cases (shared
through :func:`tools.graphlint.budgets.live_report`'s memo — one set
of compiles per process regardless of how many rules run) against the
committed pins in ``tools/graphlint/budgets.json`` and anchors its
findings at that manifest file, naming the case and the dotted field
that drifted plus the ``--update-budgets`` conscious-repin step.

Rules stay silent when no manifest exists under the lint root (the
workflow for a fresh tree is ``--update-budgets`` first), and raise a
configuration error (exit 2) when jax itself is unavailable — a
missing toolchain is a broken invocation, not a clean graph.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

from tools.lint.core import Context, Finding, LintConfigError, Rule, \
    register

#: where findings anchor (root-relative; line 0 = file-level)
ANCHOR = "tools/graphlint/budgets.json"

REPIN = ("repin consciously with "
         "python -m tools.graphlint --update-budgets")


def _manifest_and_live(ctx: Context) \
        -> Tuple[Optional[Dict], Optional[Dict]]:
    from tools.graphlint import budgets
    manifest = budgets.load_budgets(ctx.root)
    if manifest is None:
        return None, None
    try:
        live = budgets.live_report(manifest)
    except ImportError as e:
        raise LintConfigError(
            f"ir-* rules need the jax toolchain to trace engines "
            f"({e}); run in an installed environment via "
            "python -m tools.graphlint") from e
    return manifest, live


def _drift_findings(rule: str, manifest: Dict, live: Dict,
                    fields: Tuple[str, ...]) -> Iterable[Finding]:
    """Pinned-vs-live findings for one rule's field slice, over every
    traced case."""
    from tools.graphlint import budgets
    for name, got in sorted(live["cases"].items()):
        if not got:                     # unmeasurable in-process probe
            continue
        pinned = manifest["cases"][name].get("budget", {})
        for path, want, have in budgets.diff_budget(pinned, got,
                                                    fields):
            yield Finding(
                rule=rule, path=ANCHOR, line=0,
                message=(f"case {name}: {path} is pinned at {want!r} "
                         f"but the compiled engine has {have!r} — "
                         f"{REPIN}"))


class IrRule(Rule):
    """Base for the family: repo-level, non-default, no source files."""
    default = False
    suffixes: Tuple[str, ...] = ()


@register
class BudgetDriftRule(IrRule):
    name = "ir-budget-drift"
    contract = ("the compiled while-body kernel count, primitive "
                "histogram and carry footprint of every manifest case "
                "match tools/graphlint/budgets.json, and the neutral "
                "scenario stays graph-identical to scenario-free")

    FIELDS = ("while_body_kernels", "primitive_histogram")

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        from tools.graphlint import budgets
        manifest, live = _manifest_and_live(ctx)
        if manifest is None:
            return
        yield from _drift_findings(self.name, manifest, live,
                                   self.FIELDS)
        # carry bytes are budget (this rule); tensor count/dtypes are
        # discipline (ir-dtype-discipline)
        for name, got in sorted(live["cases"].items()):
            pinned = manifest["cases"][name].get("budget", {})
            want = pinned.get("carry", {}).get("total_bytes")
            have = got.get("carry", {}).get("total_bytes")
            if want != have:
                yield Finding(
                    rule=self.name, path=ANCHOR, line=0,
                    message=(f"case {name}: carry.total_bytes is "
                             f"pinned at {want!r} but the compiled "
                             f"engine carries {have!r} — {REPIN}"))
        # the committed neutrality contract: a case declaring
        # equals=<other> must pin the identical budget (and therefore,
        # via the drift checks above, compile identically live)
        for name, case in sorted(manifest["cases"].items()):
            other = case.get("equals")
            if not other:
                continue
            for path, a, b in budgets.diff_budget(
                    case.get("budget", {}),
                    manifest["cases"][other].get("budget", {})):
                yield Finding(
                    rule=self.name, path=ANCHOR, line=0,
                    message=(f"case {name} is declared graph-equal to "
                             f"{other} but their pinned budgets "
                             f"differ at {path} ({a!r} vs {b!r}) — "
                             "a neutral scenario must compile out "
                             "completely"))


@register
class DtypeDisciplineRule(IrRule):
    name = "ir-dtype-discipline"
    contract = ("the loop carry keeps its pinned tensor count and "
                "per-tensor dtypes, and the x64 graphs contain no "
                "float32 values or f64->f32 demotions beyond the "
                "manifest pins")

    FIELDS = ("carry", "float32_ops", "f64_to_f32_demotions")

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        from tools.graphlint import budgets
        manifest, live = _manifest_and_live(ctx)
        if manifest is None:
            return
        for name, got in sorted(live["cases"].items()):
            if not got:                 # unmeasurable in-process probe
                continue
            pinned = manifest["cases"][name].get("budget", {})
            for path, want, have in budgets.diff_budget(
                    pinned, got, self.FIELDS):
                if path == "carry.total_bytes":
                    continue           # ir-budget-drift owns bytes
                yield Finding(
                    rule=self.name, path=ANCHOR, line=0,
                    message=(f"case {name}: {path} is pinned at "
                             f"{want!r} but the compiled engine has "
                             f"{have!r} — {REPIN}"))


@register
class GraphPurityRule(IrRule):
    name = "ir-graph-purity"
    contract = ("compiled engine graphs contain no host callbacks, "
                "transfers or traced-RNG (threefry) primitives, and "
                "the serving virtual path stays under its pinned XLA-"
                "compilation ceiling (eager transfer kernels only, "
                "never a jitted computation)")

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        manifest, live = _manifest_and_live(ctx)
        if manifest is None:
            return
        for name, got in sorted(live["cases"].items()):
            for prim, count in sorted(
                    got.get("banned_primitives", {}).items()):
                yield Finding(
                    rule=self.name, path=ANCHOR, line=0,
                    message=(f"case {name}: banned primitive "
                             f"{prim!r} appears {count}x in the "
                             "traced graph — host callbacks, "
                             "transfers and traced RNG break the "
                             "pure-loop/CRN contract and cannot be "
                             "repinned"))
            pinned = manifest["cases"][name].get("budget", {})
            if "xla_compilations" in pinned and got \
                    and got.get("xla_compilations", 0) \
                    > pinned["xla_compilations"]:
                yield Finding(
                    rule=self.name, path=ANCHOR, line=0,
                    message=(f"case {name}: the serving virtual path "
                             f"triggered {got['xla_compilations']} "
                             "XLA compilation(s), above its pinned "
                             f"ceiling of {pinned['xla_compilations']}"
                             " (only the eager context-save/restore "
                             "transfer kernels are allowed — a jitted "
                             "model call must not enter the virtual "
                             "path)"))


@register
class DonationRule(IrRule):
    name = "ir-donation"
    contract = ("the donated lockstep carry is actually donated: the "
                "compiled modules keep their pinned input/output "
                "alias count and raise zero donation-dropped "
                "warnings")

    FIELDS = ("donation",)

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        manifest, live = _manifest_and_live(ctx)
        if manifest is None:
            return
        yield from _drift_findings(self.name, manifest, live,
                                   self.FIELDS)


@register
class RetraceSurfaceRule(IrRule):
    name = "ir-retrace-surface"
    contract = ("the span planner's distinct traced signatures over "
                "the shared corpora stay at their pinned O(1) counts "
                "and never scale per-point (the mega-batching "
                "precondition)")

    def check_repo(self, ctx: Context) -> Iterable[Finding]:
        from tools.graphlint import budgets
        manifest, live = _manifest_and_live(ctx)
        if manifest is None or "retrace" not in live:
            return
        pinned = manifest.get("retrace", {})
        for path, want, have in budgets.diff_budget(pinned,
                                                    live["retrace"]):
            yield Finding(
                rule=self.name, path=ANCHOR, line=0,
                message=(f"retrace surface: {path} is pinned at "
                         f"{want!r} but the span planner now yields "
                         f"{have!r} — {REPIN}"))
        for corpus, row in sorted(live["retrace"].items()):
            if row["n_points"] > 1 \
                    and row["signatures"] >= row["n_points"]:
                yield Finding(
                    rule=self.name, path=ANCHOR, line=0,
                    message=(f"retrace surface: corpus {corpus} "
                             f"retraces per point ({row['signatures']}"
                             f" signatures for {row['n_points']} "
                             "points) — bucketing has collapsed; "
                             "this blocks mega-batching"))
