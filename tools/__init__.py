"""Repo tooling namespace (``tools.lint`` is the static-analysis
entry point; see docs/linting.md)."""
